"""Service router: load-balances one service's requests over its instances.

MIG-Serving "relies on load balancing systems to dispatch user requests
accordingly" (§7) when a service runs with different batch sizes on
different-sized instances — this module is that system: weighted round-robin
proportional to each instance's profiled throughput.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass
class InstanceHandle:
    instance_id: int
    size: int
    throughput: float  # profiled req/s (the router weight)
    dispatched: int = 0


class WeightedRouter:
    """Deterministic smooth weighted round-robin."""

    def __init__(self, instances: Sequence[InstanceHandle]):
        assert instances, "router needs at least one instance"
        self.instances = list(instances)
        self._current = [0.0] * len(self.instances)

    def pick(self) -> InstanceHandle:
        total = sum(i.throughput for i in self.instances)
        best_i = 0
        for idx, inst in enumerate(self.instances):
            self._current[idx] += inst.throughput
            if self._current[idx] > self._current[best_i]:
                best_i = idx
        self._current[best_i] -= total
        inst = self.instances[best_i]
        inst.dispatched += 1
        return inst

    def dispatch_counts(self) -> Dict[int, int]:
        return {i.instance_id: i.dispatched for i in self.instances}
