"""Service router: load-balances one service's requests over its instances.

MIG-Serving "relies on load balancing systems to dispatch user requests
accordingly" (§7) when a service runs with different batch sizes on
different-sized instances — this module is that system: weighted round-robin
proportional to each instance's profiled throughput.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass
class InstanceHandle:
    instance_id: int
    size: int
    throughput: float  # profiled req/s (the router weight)
    dispatched: int = 0


class WeightedRouter:
    """Deterministic smooth weighted round-robin.

    The weight total is computed once at construction (throughputs are fixed
    for a router's lifetime — the simulator rebuilds the router when the
    instance set changes), not on every pick.  When the weights carry no
    signal — all zero (freshly profiled, unmeasured instances) or any
    non-finite entry — smooth WRR would degenerate to always-instance-0, so
    the router falls back to plain round-robin until it is rebuilt with real
    throughputs."""

    def __init__(self, instances: Sequence[InstanceHandle]):
        if not instances:
            raise ValueError("router needs at least one instance")
        self.instances = list(instances)
        self._current = [0.0] * len(self.instances)
        total = sum(i.throughput for i in self.instances)
        finite = all(
            t >= 0.0 and t == t and t != float("inf")
            for t in (i.throughput for i in self.instances)
        )
        self._total = total if finite and total > 0.0 else None
        self._rr = 0  # round-robin cursor for the degenerate fallback

    def pick(self) -> InstanceHandle:
        if self._total is None:  # no usable weights: plain round-robin
            inst = self.instances[self._rr]
            self._rr = (self._rr + 1) % len(self.instances)
            inst.dispatched += 1
            return inst
        best_i = 0
        for idx, inst in enumerate(self.instances):
            self._current[idx] += inst.throughput
            if self._current[idx] > self._current[best_i]:
                best_i = idx
        self._current[best_i] -= self._total
        inst = self.instances[best_i]
        inst.dispatched += 1
        return inst

    def dispatch_counts(self) -> Dict[int, int]:
        return {i.instance_id: i.dispatched for i in self.instances}
