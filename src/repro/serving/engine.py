"""Serving engine: prefill + continuous-batching decode on one instance.

An :class:`Engine` is what MIG-Serving schedules onto a GPU instance / TPU
slice: it owns the model params, a fixed-capacity batch of request *slots*,
and jit'd ``prefill`` / ``decode`` steps.  Requests join free slots, prefill
fills their KV cache, and every decode step advances all live slots by one
token (continuous batching — freed slots are refilled between steps).

The batch capacity is chosen by the scheduler per the paper's rule: "the
largest batch size possible, as far as the inference latency is smaller than
what required by SLOs" (§7).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class Engine:
    def __init__(
        self,
        model: Model,
        params: Any,
        batch: int,
        max_len: int,
        seed: int = 0,
    ):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = model.init_cache(batch, max_len)
        self.slots: List[Optional[Request]] = [None] * batch
        self.slot_pos = np.zeros(batch, np.int32)  # next position per slot
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    # -- admission ------------------------------------------------------------
    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    def admit(self, req: Request) -> int:
        slot = self.slots.index(None)
        self.slots[slot] = req
        req.submitted_s = time.monotonic()
        # prefill: feed prompt tokens one decode step at a time (correct and
        # simple; the jit'd bulk prefill path is exercised by launch/serve.py)
        pos = 0
        for t in req.prompt:
            tok = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(int(t))
            _, self.cache = self._decode(
                self.params, self.cache, tok, jnp.int32(pos)
            )
            pos += 1
        self.slot_pos[slot] = len(req.prompt)
        return slot

    # -- decode ---------------------------------------------------------------
    def step(self, rng: np.random.Generator) -> List[Request]:
        """One decode step for all live slots; returns finished requests."""
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return []
        toks = np.zeros((self.batch, 1), np.int32)
        for i in live:
            req = self.slots[i]
            toks[i, 0] = req.out_tokens[-1] if req.out_tokens else (
                req.prompt[-1] if len(req.prompt) else 0
            )
        pos = int(max(self.slot_pos[i] for i in live))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(min(pos, self.max_len - 1))
        )
        logits = np.asarray(logits.astype(jnp.float32))
        finished = []
        for i in live:
            req = self.slots[i]
            nxt = int(np.argmax(logits[i, 0]))
            req.out_tokens.append(nxt)
            self.slot_pos[i] += 1
            if req.done or self.slot_pos[i] >= self.max_len:
                req.finished_s = time.monotonic()
                finished.append(req)
                self.slots[i] = None
        self.steps += 1
        return finished


@dataclasses.dataclass
class ServeStats:
    served: int = 0
    tokens: int = 0
    wall_s: float = 0.0

    @property
    def throughput(self) -> float:
        return self.served / self.wall_s if self.wall_s else 0.0


def run_closed_loop(
    engine: Engine, requests: List[Request], seed: int = 0
) -> ServeStats:
    """Admit-and-decode until all requests finish (the Engine's test driver)."""
    rng = np.random.default_rng(seed)
    pending = list(requests)
    stats = ServeStats()
    t0 = time.monotonic()
    while pending or any(s is not None for s in engine.slots):
        while pending and engine.has_free_slot():
            engine.admit(pending.pop(0))
        for req in engine.step(rng):
            stats.served += 1
            stats.tokens += len(req.out_tokens)
    stats.wall_s = time.monotonic() - t0
    return stats
