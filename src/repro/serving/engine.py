"""Serving engine: ragged continuous batching on one instance.

An :class:`Engine` is what MIG-Serving schedules onto a GPU instance / TPU
slice: it owns the model params, a fixed-capacity batch of request *slots*,
and jit'd ``prefill`` / ``decode`` steps.  Requests join free slots; admission
runs the jit'd batch-1 :meth:`~repro.models.Model.prefill` over the prompt
and scatters the resulting cache into the slot (other slots are never
touched); every decode step advances all live slots by one token at their
*own* positions (``Model.decode_step`` takes a per-slot ``(B,)`` position
vector, with masked cache writes for idle slots).

Two KV backends:

* ``paged`` (default where supported) — attention KV lives in fixed-size
  pages from a shared :class:`~repro.serving.paged_cache.PagePool`; the
  slot's HBM budget maps to ``num_pages`` (:func:`page_hbm_bytes`), and pool
  exhaustion is an explicit signal: admission is *refused* (``OutOfPages``
  propagates to the caller) and a request that cannot grow mid-decode is
  *preempted* — its pages are released and it restarts later with its
  generated tokens folded into the prompt.  Nothing is ever silently
  clamped or overwritten.
* ``flat`` — the dense per-slot ``(B, max_len, ...)`` cache, kept as the
  reference fallback (and the only layout for MLA latent caches and
  sliding-window rings; pure-SSM models have no growing KV, so both backend
  names select their fixed-size state cache).

Sampling is seeded and explicit: ``temperature == 0`` (default) is argmax —
the deterministic mode the ragged oracle tests pin — otherwise
temperature/top-k sampling draws from the ``rng`` passed to
:meth:`Engine.step` / :meth:`Engine.admit`.

The batch capacity is chosen by the scheduler per the paper's rule: "the
largest batch size possible, as far as the inference latency is smaller than
what required by SLOs" (§7).  :func:`run_closed_loop` closes the paper's
§8.3 loop: measured throughput feeds a
:class:`~repro.core.online_profiles.MeasuredProfile` so the optimizer
consumes production-corrected profiles.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.models.config import ModelConfig
from repro.serving.paged_cache import OutOfPages, PagePool, page_bytes


@dataclasses.dataclass(eq=False)
class Request:
    # eq=False: requests are identity-compared.  A generated __eq__ would
    # tuple-compare fields including the numpy ``prompt``, so two distinct
    # requests sharing a rid would make ``pending.remove(req)`` raise on the
    # ambiguous array truth value instead of removing the right object.
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    first_token_s: float = 0.0
    finished_s: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


def attn_layer_count(cfg: ModelConfig) -> int:
    """Number of layers holding a growing attention KV cache."""
    if cfg.arch_type == "ssm":
        return 0
    if cfg.arch_type == "hybrid":
        return cfg.num_layers // cfg.shared_attn_every
    return cfg.num_layers


def page_hbm_bytes(cfg: ModelConfig, page_size: int, dtype_bytes: int = 2) -> int:
    """HBM cost of ONE logical page for this architecture — the unit a
    slice's HBM budget is divided by to get ``num_pages``."""
    return page_bytes(
        page_size, cfg.num_kv_heads, cfg.head_dim,
        attn_layer_count(cfg), dtype_bytes,
    )


class Engine:
    def __init__(
        self,
        model: Model,
        params: Any,
        batch: int,
        max_len: int,
        *,
        kv_backend: str = "auto",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        hbm_budget_bytes: Optional[int] = None,
        temperature: float = 0.0,
        top_k: int = 0,
    ):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.steps = 0
        self.slots: List[Optional[Request]] = [None] * batch
        # per-slot context length; -1 marks an idle slot (the decode-side
        # convention: negative position => masked cache writes)
        self.slot_pos = np.full(batch, -1, np.int32)
        self._finished: List[Request] = []
        self._preempted: List[Request] = []

        cfg = self.cfg
        if cfg.sliding_window and cfg.sliding_window < max_len:
            raise NotImplementedError(
                "Engine does not serve sliding-window ring caches; use the "
                "flat decode path directly (repro.launch.specs long_500k)"
            )
        if kv_backend == "auto":
            backend = "paged" if model.supports_paged_kv else "flat"
        elif kv_backend == "paged" and not model.supports_paged_kv:
            if cfg.arch_type == "ssm":
                backend = "flat"  # no growing KV to page: state cache as-is
            else:
                raise ValueError(
                    f"paged KV unsupported for {cfg.name}: "
                    f"attention_kind={cfg.attention_kind!r}"
                )
        elif kv_backend in ("paged", "flat"):
            backend = kv_backend
        else:
            raise ValueError(f"unknown kv_backend {kv_backend!r}")
        self.kv_backend = backend

        if backend == "paged":
            max_pages_per_req = -(-max_len // page_size)  # ceil
            if num_pages is None:
                if hbm_budget_bytes is not None:
                    num_pages = hbm_budget_bytes // max(1, page_hbm_bytes(cfg, page_size))
                else:
                    num_pages = batch * max_pages_per_req
            if num_pages < 1:
                raise ValueError(
                    f"HBM budget yields num_pages={num_pages}; need >= 1"
                )
            self.pool: Optional[PagePool] = PagePool(
                num_pages, page_size, max_pages_per_req
            )
            self.cache = model.init_paged_cache(
                batch, num_pages, page_size, max_pages_per_req
            )
            self._decode = jax.jit(model.decode_step_paged)
        else:
            self.pool = None
            self.cache = model.init_cache(batch, max_len)
            self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, toks, lens: model.prefill(p, tokens=toks, lengths=lens)
        )
        # Prompts are right-padded (exact — dt-masked SSM states, masked-out
        # attention rows, true-last-token logits; see Model.prefill) so the
        # jit'd prefill compiles one trace per length *bucket*, not per
        # distinct prompt/resume length.  SSM needs chunk alignment anyway;
        # MoE must see exact lengths because padded tokens would compete for
        # expert capacity and perturb real-token outputs.
        if cfg.arch_type in ("ssm", "hybrid"):
            self._pad_to = cfg.ssm_chunk
        elif cfg.arch_type == "moe":
            self._pad_to = 1
        else:
            self._pad_to = 16

    # -- introspection --------------------------------------------------------
    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    @property
    def num_live(self) -> int:
        return sum(s is not None for s in self.slots)

    def take_preempted(self) -> List[Request]:
        """Requests evicted on pool exhaustion since the last call; re-admit
        them (their generated tokens resume from the prompt) once capacity
        frees up."""
        out, self._preempted = self._preempted, []
        return out

    # -- admission ------------------------------------------------------------
    def admit(self, req: Request, rng: Optional[np.random.Generator] = None) -> int:
        """Admit one request: batch-1 jit'd prefill over its context, cache
        scattered into a free slot, first output token sampled from the
        prefill logits.

        Raises :class:`OutOfPages` (paged backend) when the pool cannot hold
        the context plus one decode token — the admission-control signal; the
        request is left untouched for the caller to retry later."""
        ctx = np.asarray(req.prompt, np.int32)
        if req.out_tokens:  # resuming after preemption
            ctx = np.concatenate([ctx, np.asarray(req.out_tokens, np.int32)])
        L = int(ctx.size)
        if L < 1:
            raise ValueError("empty prompt")
        if L + 1 > self.max_len:
            raise ValueError(
                f"context length {L} does not fit max_len={self.max_len}"
            )
        slot = self.slots.index(None)
        if self.pool is not None:
            self.pool.admit(req.rid)
            try:
                # context + room for the first decode write (so an admitted
                # request can always take at least one step)
                self.pool.append_tokens(req.rid, L + 1)
            except OutOfPages:
                self.pool.release(req.rid)
                raise
        try:
            pad = -(-L // self._pad_to) * self._pad_to
            toks = np.zeros((1, pad), np.int32)
            toks[0, :L] = ctx
            logits, pcache = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray([L], jnp.int32)
            )
            page_ids = (
                self.pool.request(req.rid).page_ids
                if self.pool is not None
                else None
            )
            self.cache = self.model.scatter_prefill(
                self.cache, pcache, slot, L, page_ids
            )
            self.slots[slot] = req
            self.slot_pos[slot] = L
            if req.submitted_s == 0.0:
                req.submitted_s = time.monotonic()
            first = self._sample(
                np.asarray(logits.astype(jnp.float32))[0, 0], rng
            )
            req.out_tokens.append(first)
            if req.first_token_s == 0.0:
                req.first_token_s = time.monotonic()
        except BaseException:
            # prefill/scatter/sampling failed after the pages were reserved:
            # undo the reservation (free list byte-identical, stale rid entry
            # dropped so a retry of the same rid re-admits cleanly) and free
            # the slot — the OutOfPages contract says a failed admission
            # leaves the engine untouched.
            self.slots[slot] = None
            self.slot_pos[slot] = -1
            if self.pool is not None:
                self.pool.abort(req.rid)
            raise
        if req.done:
            self._finish(slot)
        return slot

    # -- decode ---------------------------------------------------------------
    def step(self, rng: Optional[np.random.Generator] = None) -> List[Request]:
        """One ragged decode step for all live slots; returns finished
        requests (including any that completed at admission since the last
        step).  Paged backend: slots that cannot allocate their next token's
        page are preempted first (see :meth:`take_preempted`)."""
        finished, self._finished = self._finished, []
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return finished
        if self.pool is not None:
            for i in list(live):
                req = self.slots[i]
                need = int(self.slot_pos[i]) + 1 - self.pool.request(req.rid).length
                if need > 0:
                    try:
                        self.pool.append_tokens(req.rid, need)
                    except OutOfPages:
                        self._preempt(i)
                        live.remove(i)
            if not live:
                return finished
            self._refresh_page_tables()
        toks = np.zeros((self.batch, 1), np.int32)
        pos = np.full(self.batch, -1, np.int32)
        for i in live:
            toks[i, 0] = self.slots[i].out_tokens[-1]
            pos[i] = self.slot_pos[i]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos)
        )
        lg = np.asarray(logits.astype(jnp.float32))
        for i in live:
            req = self.slots[i]
            self.slot_pos[i] += 1
            req.out_tokens.append(self._sample(lg[i, 0], rng))
            if req.done or self.slot_pos[i] >= self.max_len:
                self._finish(i)
        self.steps += 1
        finished.extend(self._finished)
        self._finished = []
        return finished

    # -- internals ------------------------------------------------------------
    def _sample(
        self, logits_row: np.ndarray, rng: Optional[np.random.Generator]
    ) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        if rng is None:
            raise ValueError("temperature > 0 requires an rng")
        z = logits_row.astype(np.float64) / self.temperature
        if self.top_k and self.top_k < z.size:
            # exactly k candidates: a >= kth-value cut would keep *every*
            # logit tied with the k-th and sample from more than k on ties.
            # Stable sort makes the tie order deterministic (lowest index
            # wins), so seeded runs stay reproducible.
            keep = np.argsort(-z, kind="stable")[: self.top_k]
            cut = np.full_like(z, -np.inf)
            cut[keep] = z[keep]
            z = cut
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(rng.choice(z.size, p=p))

    def _finish(self, slot: int) -> None:
        req = self.slots[slot]
        req.finished_s = time.monotonic()
        self.slots[slot] = None
        self.slot_pos[slot] = -1
        if self.pool is not None:
            self.pool.release(req.rid)
        self._finished.append(req)

    def _preempt(self, slot: int) -> None:
        req = self.slots[slot]
        # Re-admission prefills prompt + out_tokens (slot_pos + 1 tokens) and
        # needs one more decode position; a request already at the context
        # cap cannot resume — finish it truncated, exactly as the
        # non-preempted max_len path would.
        if int(self.slot_pos[slot]) + 2 > self.max_len:
            self._finish(slot)
            return
        self.slots[slot] = None
        self.slot_pos[slot] = -1
        self.pool.release(req.rid)
        self._preempted.append(req)

    def _refresh_page_tables(self) -> None:
        rids = [s.rid if s is not None else None for s in self.slots]
        pt, _ = self.pool.tables(rids)
        self.cache["page_tables"] = jnp.asarray(pt)


@dataclasses.dataclass
class ServeStats:
    served: int = 0
    tokens: int = 0
    preempted: int = 0
    refused: int = 0  # OutOfPages admission refusals (request stays pending)
    wall_s: float = 0.0
    # per-request latency observations (wall clock): time-to-first-token and
    # mean time-per-output-token — the measured twins of the token-level
    # serving model's TTFT/TPOT metrics (repro.sim.servemodel)
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    tpot_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def throughput(self) -> float:
        return self.served / self.wall_s if self.wall_s else 0.0

    def summary(self, service: str = "engine") -> Dict[str, Any]:
        """The engine-side stats in the simulator's ``obs`` metrics schema
        (``launch/serve.py --stats-json`` writes exactly this), so real-run
        and simulated TTFT/TPOT read side by side: counters under the
        ``serving.*`` names the :class:`repro.obs.MetricsRegistry` uses,
        latency percentiles via the shared ``percentile_summary`` keys."""
        from repro.obs.metrics import percentile_summary

        return {
            "service": service,
            "counters": {
                "serving.completed": float(self.served),
                "serving.preemptions": float(self.preempted),
                "serving.refusals": float(self.refused),
                "serving.tokens": float(self.tokens),
            },
            "latency": {
                **percentile_summary(self.ttft_s, "ttft"),
                **percentile_summary(self.tpot_s, "tpot"),
            },
            "throughput_rps": self.throughput,
            "wall_s": self.wall_s,
        }


def run_closed_loop(
    engine: Engine,
    requests: List[Request],
    seed: int = 0,
    measured: Optional[Any] = None,  # repro.core.online_profiles.MeasuredProfile
    service: Optional[str] = None,
    size: Optional[int] = None,
) -> ServeStats:
    """Admit-and-decode until all requests finish (the Engine's test driver).

    Preempted requests are re-queued at the front (their generated tokens
    resume from the prompt); admission refusals (``OutOfPages``) leave the
    request pending until capacity frees up.  When ``measured`` (a
    :class:`~repro.core.online_profiles.MeasuredProfile`) plus ``service``
    and ``size`` are given, the measured throughput is fed back into the
    profile — the paper's §8.3 production-measurement loop."""
    rng = np.random.default_rng(seed)
    pending = list(requests)
    stats = ServeStats()
    t0 = time.monotonic()
    while stats.served < len(requests):
        admitted = False
        # first-fit admission: a request the pool cannot hold right now must
        # not block admittable requests queued behind it
        for req in list(pending):
            if not engine.has_free_slot():
                break
            try:
                engine.admit(req, rng)
            except OutOfPages:
                stats.refused += 1
                continue
            pending.remove(req)
            admitted = True
        finished = engine.step(rng)
        for req in finished:
            stats.served += 1
            stats.tokens += len(req.out_tokens)
            if req.first_token_s > 0.0:
                stats.ttft_s.append(req.first_token_s - req.submitted_s)
                if len(req.out_tokens) > 1:
                    stats.tpot_s.append(
                        (req.finished_s - req.first_token_s)
                        / (len(req.out_tokens) - 1)
                    )
        preempted = engine.take_preempted()
        stats.preempted += len(preempted)
        pending = preempted + pending
        # Stuck only if this iteration made no progress of any kind —
        # a preemption frees pages the next admission pass can use.
        if (not finished and not admitted and not preempted
                and engine.num_live == 0 and pending):
            raise RuntimeError(
                f"requests {[r.rid for r in pending]} cannot be admitted: "
                f"page pool too small for their contexts"
            )
    stats.wall_s = time.monotonic() - t0
    if measured is not None and service is not None and size is not None:
        if stats.wall_s > 0:
            measured.observe(service, size, engine.batch, stats.throughput)
    return stats
