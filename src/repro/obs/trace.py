"""Sim-time span tracing, exportable as Chrome trace-event JSON.

A :class:`SpanTracer` records *simulation-time* spans — never wall clock;
this module deliberately does not import :mod:`time` or :mod:`datetime`,
and the test suite greps for it — organized into named tracks (one Chrome
"thread" per track).  The export is the Chrome trace-event format
(``{"traceEvents": [...]}``), which https://ui.perfetto.dev loads directly,
so a simulated reoptimize cycle, fault arc, or token-serving bin renders on
the same timeline UI real profilers use.

Two recording styles:

* ``span(track, name, t0, t1)`` — a complete event whose endpoints are
  already known (most simulator instrumentation sites: the event loop knows
  when a phase starts and ends).
* ``begin(track, name, t)`` / ``end(track, t)`` — a stack discipline for
  callers that discover the end later.  Nesting is enforced: a child must
  begin at or after its parent, ``end`` without a matching ``begin`` raises,
  and :meth:`assert_well_formed` flags spans left open.

Everything is deterministic: same call sequence, byte-identical
:meth:`export_json` (insertion-ordered events, sorted keys).  The
:class:`NullTracer` is the zero-cost default when observability is off —
every method is a no-op, so instrumentation sites cost one attribute check.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

# sim seconds -> trace microseconds (the trace-event format's "ts" unit)
_US = 1e6


class NullTracer:
    """No-op twin of :class:`SpanTracer` (observability off)."""

    enabled = False

    def span(self, track, name, t0, t1, args=None):  # noqa: D102
        pass

    def instant(self, track, name, t, args=None):  # noqa: D102
        pass

    def begin(self, track, name, t, args=None):  # noqa: D102
        pass

    def end(self, track, t, args=None):  # noqa: D102
        pass

    def assert_well_formed(self):  # noqa: D102
        pass

    def span_summary(self) -> Dict:  # noqa: D102
        return {}

    def chrome_trace(self) -> Dict:  # noqa: D102
        return {"displayTimeUnit": "ms", "traceEvents": []}

    def export_json(self) -> str:  # noqa: D102
        return json.dumps(
            self.chrome_trace(), sort_keys=True, separators=(",", ":")
        )


class SpanTracer:
    """Records sim-time spans/instants on named tracks (see module doc)."""

    enabled = True

    def __init__(self) -> None:
        self._events: List[Dict] = []  # finalized, insertion order
        self._tracks: Dict[str, int] = {}  # track name -> tid
        # per-track stack of open begin() frames: (name, t0, args)
        self._open: Dict[str, List[Tuple[str, float, Optional[Dict]]]] = {}

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks)
        return tid

    # -- recording ---------------------------------------------------------------
    def span(
        self,
        track: str,
        name: str,
        t0: float,
        t1: float,
        args: Optional[Dict] = None,
    ) -> None:
        """A complete event covering sim-time ``[t0, t1]`` (``t1 == t0`` is
        a zero-duration span: valid trace-event JSON, rendered as a tick)."""
        if t1 < t0 - 1e-12:
            raise ValueError(f"span {name!r} ends before it starts: {t0} -> {t1}")
        self._events.append(
            {
                "name": name,
                "cat": track,
                "ph": "X",
                "ts": t0 * _US,
                "dur": max(t1 - t0, 0.0) * _US,
                "pid": 0,
                "tid": self._tid(track),
                "args": dict(args or {}),
            }
        )

    def instant(
        self, track: str, name: str, t: float, args: Optional[Dict] = None
    ) -> None:
        """A zero-extent marker (trace-event phase ``i``)."""
        self._events.append(
            {
                "name": name,
                "cat": track,
                "ph": "i",
                "s": "t",  # thread-scoped marker
                "ts": t * _US,
                "pid": 0,
                "tid": self._tid(track),
                "args": dict(args or {}),
            }
        )

    def begin(
        self, track: str, name: str, t: float, args: Optional[Dict] = None
    ) -> None:
        """Open a span on ``track``; must be closed by :meth:`end`.  A child
        span may not begin before its parent did (overlap violation)."""
        stack = self._open.setdefault(track, [])
        if stack and t < stack[-1][1] - 1e-12:
            raise ValueError(
                f"span {name!r} on track {track!r} begins at {t} before its "
                f"parent {stack[-1][0]!r} began at {stack[-1][1]}"
            )
        stack.append((name, t, dict(args) if args else None))

    def end(self, track: str, t: float, args: Optional[Dict] = None) -> None:
        """Close the innermost open span on ``track``."""
        stack = self._open.get(track)
        if not stack:
            raise RuntimeError(f"end() without begin() on track {track!r}")
        name, t0, open_args = stack.pop()
        merged = dict(open_args or {})
        merged.update(args or {})
        self.span(track, name, t0, t, args=merged)

    # -- integrity ---------------------------------------------------------------
    def assert_well_formed(self) -> None:
        """Every begin() was closed — call before export."""
        leaked = {
            track: [name for name, _t0, _a in stack]
            for track, stack in self._open.items()
            if stack
        }
        if leaked:
            raise RuntimeError(f"spans left open at export: {leaked}")

    # -- export ------------------------------------------------------------------
    def span_summary(self) -> Dict:
        """Counts only (serialized into ``SimReport.obs`` — the full event
        list lives in the trace export, not the report)."""
        per_track: Dict[str, int] = {t: 0 for t in self._tracks}
        for ev in self._events:
            per_track[ev["cat"]] += 1
        return {
            "events": len(self._events),
            "tracks": dict(sorted(per_track.items())),
        }

    def chrome_trace(self) -> Dict:
        """The trace-event document: thread-name metadata (one per track, so
        Perfetto labels the rows) followed by the recorded events."""
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": 0,
                "tid": tid,
                "args": {"name": track},
            }
            for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1])
        ]
        return {"displayTimeUnit": "ms", "traceEvents": meta + self._events}

    def export_json(self) -> str:
        """Canonical serialization: byte-identical across same-seed runs."""
        self.assert_well_formed()
        return json.dumps(
            self.chrome_trace(), sort_keys=True, separators=(",", ":")
        )
