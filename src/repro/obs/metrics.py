"""Counter/gauge/histogram registry with deterministic snapshots.

Naming convention (the docs and the serve CLI's ``--stats-json`` follow
it): ``subsystem.metric`` in lowercase, dot-separated — e.g.
``serving.preemptions``, ``pages.used``, ``reconcile.retried``,
``queue.depth.critical``.  Time-valued metrics carry an ``_s`` suffix
(sim seconds — this module never reads wall clock; like
:mod:`repro.obs.trace` it does not import :mod:`time`).

Three metric kinds:

* :class:`Counter` — monotone total; ``inc(v)`` adds, ``inc_to(total)``
  advances to an externally-tracked cumulative value (handy when the
  instrumented subsystem already keeps running totals).
* :class:`Gauge` — last-set value (``set(v)``).
* :class:`Histogram` — fixed exponential bounds, ``observe(v)`` buckets it.

:meth:`MetricsRegistry.sample` snapshots every counter and gauge at a sim
time, building the per-bin series the ``SimReport.obs`` block serializes.
Metrics created after sampling started are back-filled with zeros, so all
series stay aligned.  :meth:`snapshot` returns a sorted, JSON-ready dict —
same call sequence, byte-identical serialization.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

# percentiles the summaries report, shared with the sim's latency block and
# the serve CLI's --stats-json (keys like "ttft_p50_s")
PCTS = (50.0, 95.0, 99.0)

# default histogram bucket upper bounds (seconds-flavored exponential grid;
# the final +inf bucket is implicit)
_DEFAULT_BOUNDS = (0.01, 0.1, 1.0, 10.0, 100.0, 1000.0)


def percentile_summary(
    vals: Sequence[float], prefix: str, pcts: Sequence[float] = PCTS
) -> Dict[str, float]:
    """``{prefix}_p{P}_s`` percentile keys over ``vals`` (0.0 when empty) —
    the schema shared by the simulator's latency block, the ``obs`` metrics
    block, and the real engine's ``--stats-json``."""
    if not vals:
        return {f"{prefix}_p{int(p)}_s": 0.0 for p in pcts}
    a = np.asarray(vals, dtype=np.float64)
    return {f"{prefix}_p{int(p)}_s": float(np.percentile(a, p)) for p in pcts}


class Counter:
    """Monotone running total."""

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v

    def inc_to(self, total: float) -> None:
        """Advance to an externally-tracked cumulative ``total``."""
        if total < self.value - 1e-9:
            raise ValueError(
                f"counter cannot move backwards: {self.value} -> {total}"
            )
        self.value = max(self.value, float(total))


class Gauge:
    """Last-set value."""

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bound bucket counts plus running sum/count."""

    def __init__(self, bounds: Sequence[float] = _DEFAULT_BOUNDS) -> None:
        self.bounds = tuple(float(b) for b in bounds)
        self.buckets = [0] * (len(self.bounds) + 1)  # trailing +inf bucket
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        i = int(np.searchsorted(self.bounds, v, side="left"))
        self.buckets[i] += 1
        self.count += 1
        self.total += float(v)


class _NullMetric:
    """Accepts every metric-mutation call and does nothing."""

    def inc(self, v: float = 1.0) -> None:
        pass

    def inc_to(self, total: float) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


class NullRegistry:
    """No-op twin of :class:`MetricsRegistry` (observability off)."""

    enabled = False
    _NULL = _NullMetric()

    def counter(self, name: str) -> _NullMetric:
        return self._NULL

    def gauge(self, name: str) -> _NullMetric:
        return self._NULL

    def histogram(self, name: str) -> _NullMetric:
        return self._NULL

    def sample(self, t_s: float) -> None:
        pass

    def snapshot(self) -> Dict:
        return {}


class MetricsRegistry:
    """Get-or-create metric registry with per-bin sampled series."""

    enabled = True

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._sample_t: List[float] = []
        self._series: Dict[str, List[float]] = {}  # "counter:x" / "gauge:x"

    def _get(self, table: Dict, name: str, make, kind: str):
        m = table.get(name)
        if m is None:
            for other_kind, other in (
                ("counter", self._counters),
                ("gauge", self._gauges),
                ("histogram", self._histograms),
            ):
                if kind != other_kind and name in other:
                    raise ValueError(
                        f"metric {name!r} already registered as a {other_kind}"
                    )
            m = table[name] = make()
            if kind in ("counter", "gauge"):
                # back-fill so every series spans all samples taken so far
                self._series[f"{kind}:{name}"] = [0.0] * len(self._sample_t)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge, "gauge")

    def histogram(
        self, name: str, bounds: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get(
            self._histograms,
            name,
            (lambda: Histogram(bounds)) if bounds else Histogram,
            "histogram",
        )

    # -- sampling ----------------------------------------------------------------
    def sample(self, t_s: float) -> None:
        """Record every counter's and gauge's current value at sim ``t_s``
        (the simulator calls this once per traffic bin)."""
        self._sample_t.append(float(t_s))
        for name, c in self._counters.items():
            self._series[f"counter:{name}"].append(c.value)
        for name, g in self._gauges.items():
            self._series[f"gauge:{name}"].append(g.value)

    # -- export ------------------------------------------------------------------
    def snapshot(self) -> Dict:
        """Sorted JSON-ready dict: final values plus the sampled series."""
        return {
            "counters": {
                name: c.value for name, c in sorted(self._counters.items())
            },
            "gauges": {
                name: g.value for name, g in sorted(self._gauges.items())
            },
            "histograms": {
                name: {
                    "bounds": list(h.bounds),
                    "buckets": list(h.buckets),
                    "count": h.count,
                    "total": h.total,
                }
                for name, h in sorted(self._histograms.items())
            },
            "series": {
                "t_s": list(self._sample_t),
                "counters": {
                    name: self._series[f"counter:{name}"]
                    for name in sorted(self._counters)
                },
                "gauges": {
                    name: self._series[f"gauge:{name}"]
                    for name in sorted(self._gauges)
                },
            },
        }
