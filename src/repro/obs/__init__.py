"""Flight-recorder observability: sim-time tracing, metrics, lifecycles.

numpy-only and jax-free (the same import contract as ``repro.core`` /
``repro.sim`` — the jax-free pin test covers this package too), and
strictly *sim-time*: nothing in here reads wall clock, so enabling
observability can never perturb the deterministic report bytes it watches.

Three layers, bundled by :class:`Observability`:

* :mod:`repro.obs.trace` — :class:`SpanTracer`: sim-time spans on named
  tracks, exported as Chrome trace-event JSON (open in Perfetto).
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry`: counters, gauges,
  histograms, sampled per traffic bin into deterministic series.
* :mod:`repro.obs.flight` — :class:`FlightRecorder`: bounded per-request
  lifecycle records for the token serving model.

Everything defaults to the null implementations (``Observability.off()``),
so the instrumented code paths cost one attribute check when the
``SimConfig.observability`` flag is off — and the historical report bytes
stay identical, which the golden tests pin.  See ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    percentile_summary,
)
from repro.obs.trace import NullTracer, SpanTracer

__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "NullTracer",
    "Observability",
    "SpanTracer",
    "percentile_summary",
]


@dataclasses.dataclass
class Observability:
    """The bundle the simulator threads through its layers."""

    enabled: bool
    tracer: Union[SpanTracer, NullTracer]
    metrics: Union[MetricsRegistry, NullRegistry]
    flight: Optional[FlightRecorder]

    @classmethod
    def off(cls) -> "Observability":
        """Null everything — the zero-cost default."""
        return cls(False, NullTracer(), NullRegistry(), None)

    @classmethod
    def on(cls, record_limit: int = 256) -> "Observability":
        return cls(
            True, SpanTracer(), MetricsRegistry(), FlightRecorder(record_limit)
        )
