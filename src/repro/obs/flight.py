"""Per-request flight recorder for the token-level serving model.

Aggregates (a p99 TTFT, 92.7k refusals) say *that* a cell suffered; the
flight recorder says *why*: it captures each :class:`TokenRequest`'s
lifecycle as an ordered event list —

    arrival -> queued -> admitted -> first_token
            -> (preempted | resumed | refused | backoff | migrated
                | crashed)* -> completed | deadline_dropped
                | retry_dropped | shed | truncated

with a cause attribute on every terminal event, so a tail-latency request
can be read end to end.  Recording is bounded: only the first
``record_limit`` distinct requests get event lists; later requests bump the
explicit ``truncated`` counter instead of growing memory without bound (a
micro-scale flash-crowd cell makes tens of thousands of requests).

Timestamps are sim seconds (``t_s`` — never wall clock; this module does
not import :mod:`time`).  The snapshot is deterministic: requests sorted by
rid, keys sorted by the report serializer.
"""

from __future__ import annotations

from typing import Dict, List, Optional


class FlightRecorder:
    """Bounded per-request lifecycle capture (see module doc)."""

    def __init__(self, record_limit: int = 256) -> None:
        if record_limit < 0:
            raise ValueError(f"record_limit must be >= 0, got {record_limit}")
        self.record_limit = int(record_limit)
        self.truncated = 0  # requests seen past the limit (not recorded)
        self._records: Dict[int, Dict] = {}  # rid -> record

    # -- recording ---------------------------------------------------------------
    def arrival(
        self,
        rid: int,
        service: str,
        t_s: float,
        priority: int = 1,
        deadline_s: Optional[float] = None,
    ) -> None:
        """Open a record (or count it against the truncation budget)."""
        if rid in self._records:
            return
        if len(self._records) >= self.record_limit:
            self.truncated += 1
            return
        rec: Dict = {
            "rid": rid,
            "service": service,
            "arrival_s": float(t_s),
            "priority": int(priority),
            "outcome": "in_system",
            "cause": "",
            "preemptions": 0,
            "retries": 0,
            "events": [{"event": "arrival", "t_s": float(t_s)}],
        }
        if deadline_s is not None and deadline_s != float("inf"):
            rec["deadline_s"] = float(deadline_s)
        self._records[rid] = rec

    def note(self, rid: int, event: str, t_s: float, **attrs) -> None:
        """Append one lifecycle event to ``rid``'s record (no-op when the
        request fell past the record limit)."""
        rec = self._records.get(rid)
        if rec is None:
            return
        ev: Dict = {"event": event, "t_s": float(t_s)}
        for k in sorted(attrs):
            ev[k] = attrs[k]
        rec["events"].append(ev)
        if event in ("preempted", "migrated", "crashed"):
            rec["preemptions"] += 1
        elif event == "backoff":
            rec["retries"] += 1

    def close(self, rid: int, outcome: str, t_s: float, cause: str = "") -> None:
        """Terminal event with cause attribution (``completed``,
        ``deadline_dropped``, ``retry_dropped``, ``shed``, ``truncated``)."""
        rec = self._records.get(rid)
        if rec is None:
            return
        rec["outcome"] = outcome
        rec["cause"] = cause
        rec["events"].append(
            {"event": outcome, "t_s": float(t_s), **({"cause": cause} if cause else {})}
        )

    # -- export ------------------------------------------------------------------
    def snapshot(self) -> Dict:
        """JSON-ready dict: rid-sorted records + the truncation accounting."""
        return {
            "record_limit": self.record_limit,
            "tracked": len(self._records),
            "truncated": self.truncated,
            "requests": [
                self._records[rid] for rid in sorted(self._records)
            ],
        }
